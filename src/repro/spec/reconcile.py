"""Reconcile a WorkloadSpec into an executor-backed job.

``WorkloadReconciler`` is the single submission path behind
``FluxInstance.apply(spec)``:

1. **Validate at submit time.**  Structural validation
   (``spec.validate``) plus cluster-aware checks — capacity against the
   cluster's registered hosts, serve-ability of the arch, and the comm
   policy under ``comm_strict`` probed on the very mesh the allocation
   would produce (``match_pod_local`` peek -> ``submesh_for`` ->
   ``comm.resolve_policy``, the SAME functions the step builder calls,
   so validator and runtime cannot disagree).  Bad specs raise
   :class:`repro.spec.workload.SpecError` before anything is queued.
2. **Bind the executor from the spec.**  (kind, elastic) selects the
   executor class; spec knobs configure it; executors are cached per
   configuration so same-shaped workloads share compiled steps/engines.
3. **Dispatch + lifecycle.**  The reconciler installs itself as the
   instance's executor and routes each scheduled job to its handle's
   executor, driving the handle through Pending -> Bound -> Running ->
   (Resizing ->)* Completed/Failed.  Jobs submitted outside ``apply``
   (plain ``JobSpec``s) fall through to whatever executor the instance
   had before — sim workloads keep working.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Tuple

from repro.core.jobspec import Job, JobSpec, JobState
from repro.spec.handle import (BOUND, COMPLETED, FAILED, RUNNING,
                               WorkloadHandle)
from repro.spec.workload import SpecError, WorkloadSpec, _err


class _DryRunExecutor:
    """Validation-only workload: bind resources, resolve the sharding /
    comm decisions the allocation implies, run no compute.  The record
    in ``ran`` is the point of the job."""

    def __init__(self, clock, net, tbon_fanout: int = 2, strategy=None):
        self.clock = clock
        self.net = net
        self.k = tbon_fanout
        self.strategy = strategy
        self.ran: Dict[int, Dict] = {}

    def __call__(self, job: Job, rset, done):
        from repro.comm import resolve_policy
        from repro.configs import BASELINE
        from repro.core.executor import tbon_bootstrap_cost
        from repro.dist.sharding import submesh_for
        mesh = submesh_for(rset)
        strategy = self.strategy or BASELINE
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            policy = resolve_policy(strategy, mesh)
        self.ran[job.jobid] = {
            "mesh_shape": tuple(mesh.devices.shape),
            "n_devices": int(mesh.size),
            "hosts": list(rset.hosts),
            "strategy": strategy.name,
            "comm": {"hierarchical": policy.hierarchical,
                     "compress": policy.compress},
        }
        wall = tbon_bootstrap_cost(self.net, rset.n_hosts, self.k)
        self.clock.call_in(wall, done, "completed", wall)


class WorkloadReconciler:
    """Per-instance spec -> executor reconciliation + dispatch."""

    def __init__(self, instance):
        self.instance = instance
        self.handles: Dict[int, WorkloadHandle] = {}
        self._executors: Dict[Tuple, Any] = {}
        # plain JobSpec submissions keep their pre-apply executor
        self._fallback = instance.executor
        instance.executor = self._dispatch

    # -- the ONE submission path -------------------------------------------
    def apply(self, spec: WorkloadSpec, *, cfg=None, strategy=None,
              executor_opts: Optional[Dict[str, Any]] = None
              ) -> WorkloadHandle:
        errors = spec.errors(known_arch=cfg is None)
        if not errors:
            strategy = strategy if strategy is not None \
                else spec.resolved_strategy
            cfg = cfg if cfg is not None else self._registry_cfg(spec)
            errors = self._cluster_errors(spec, cfg, strategy)
        if errors:
            raise SpecError(errors)
        ex = self._executor_for(spec, cfg, strategy,
                                dict(executor_opts or {}))
        # a replicated serve fleet binds ONE allocation covering every
        # replica; the executor slices it into per-replica submeshes
        replicas = spec.serve.replicas if spec.kind == "serve" else 1
        job = self.instance.submit(JobSpec(
            n_nodes=spec.resources.n_nodes * max(replicas, 1),
            walltime=spec.walltime,
            user=spec.user,
            urgency=spec.urgency,
            command=spec.arch,
            attributes={"workload": spec.kind,
                        "pod_local": spec.resources.pod_local,
                        "elastic": spec.resources.elastic,
                        "replicas": max(replicas, 1),
                        "spec_name": spec.name},
            args=self._job_args(spec)))
        handle = WorkloadHandle(spec, job, ex, self.instance.clock)
        self.handles[job.jobid] = handle
        self.instance.clock.trace("workload_applied", jobid=job.jobid,
                                  workload=spec.kind, name=spec.name)
        return handle

    @staticmethod
    def _registry_cfg(spec: WorkloadSpec):
        from repro.configs import registry
        return registry.smoke(spec.arch)

    @staticmethod
    def _job_args(spec: WorkloadSpec) -> Dict[str, Any]:
        if spec.kind != "serve":
            return {}
        s = spec.serve
        return {"max_new": s.max_new, "temperature": s.temperature,
                "n_requests": s.n_requests, "replicas": s.replicas,
                "tenant": s.tenant, "ttft_slo_s": s.ttft_slo_s}

    # -- cluster-aware validation ------------------------------------------
    def _cluster_errors(self, spec, cfg, strategy):
        errs = []
        inst = self.instance
        r = spec.resources
        if r.elastic and getattr(inst, "minicluster", None) is None:
            errs.append(_err(
                "resources.elastic", "no-minicluster",
                "elastic workloads need a MiniCluster-managed instance "
                "(resize events come from FluxMiniCluster.patch_size)"))
        capacity = self._capacity()
        replicas = spec.serve.replicas if spec.kind == "serve" else 1
        need = r.n_nodes * max(replicas, 1)
        if capacity and need > capacity:
            detail = (f"n_nodes={r.n_nodes}" if replicas <= 1 else
                      f"replicas={replicas} x n_nodes={r.n_nodes} = "
                      f"{need} hosts")
            errs.append(_err(
                "resources.n_nodes", "over-capacity",
                f"{detail} exceeds the cluster's maximum of "
                f"{capacity} hosts — the job could never be scheduled"))
        if spec.kind == "serve":
            if cfg.encoder_layers:
                errs.append(_err(
                    "arch", "not-servable",
                    f"{cfg.name}: the serving engine hosts decoder-only "
                    "architectures (encoder_layers > 0)"))
            elif cfg.pos_type not in ("rope", "none"):
                errs.append(_err(
                    "arch", "not-servable",
                    f"{cfg.name}: per-slot positions need rope (or no) "
                    f"position encoding, not {cfg.pos_type!r}"))
        errs.extend(self._comm_errors(spec, strategy))
        return errs

    def _capacity(self) -> int:
        mc = getattr(self.instance, "minicluster", None)
        if mc is not None:
            return mc.spec.effective_max
        return len(self.instance.graph.hosts)

    def _comm_errors(self, spec, strategy):
        """Probe the comm policy on the mesh this allocation would get.

        Only ``comm_strict`` strategies can fail here (non-strict ones
        degrade with a warning at step build).  The probe reuses the
        scheduler's own matcher and the step builder's own policy
        resolver; when the cluster has no hosts yet (pre-``create``)
        there is no mesh to probe and the check is skipped.
        """
        if not strategy.comm_strict:
            return []
        if not (strategy.hierarchical_collectives
                or strategy.compress_cross_pod):
            return []
        from repro.comm import CommTopologyError, resolve_policy
        from repro.dist.sharding import submesh_for
        inst = self.instance
        n = spec.resources.n_nodes
        rset = (inst.match_pod_local(n) if spec.resources.pod_local
                else inst.graph.match(n, policy=inst.match_policy))
        if rset is None:
            return []                   # nothing to probe yet
        mesh = submesh_for(rset)
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")
                resolve_policy(strategy, mesh)
        except CommTopologyError as e:
            return [_err(
                "strategy", "comm-strict",
                f"comm_strict: the {dict(mesh.shape)} mesh this "
                f"allocation resolves to cannot honor the requested "
                f"schedule ({e})")]
        return []

    # -- executor binding ---------------------------------------------------
    def _executor_for(self, spec, cfg, strategy, opts):
        key = (spec.kind, spec.resources.elastic, cfg, strategy,
               dataclasses.astuple(spec.train),
               dataclasses.astuple(spec.serve),
               tuple(sorted(opts.items())))
        ex = self._executors.get(key)
        if ex is not None:
            return ex
        inst = self.instance
        clock, net = inst.clock, inst.net
        mc = getattr(inst, "minicluster", None)
        if spec.kind == "train" and spec.resources.elastic:
            from repro.core.executor import ElasticTrainExecutor
            t = spec.train
            ex = ElasticTrainExecutor(
                clock, net, total_steps=t.total_steps,
                chunk_steps=t.chunk_steps, seq_len=t.seq_len,
                global_batch=t.global_batch, strategy=strategy, cfg=cfg,
                ckpt_root=t.ckpt_dir, **opts).bind(mc)
        elif spec.kind == "train":
            from repro.core.executor import SubmeshExecutor
            opts.setdefault("steps", spec.train.total_steps)
            ex = SubmeshExecutor(clock, net, seq_len=spec.train.seq_len,
                                 strategy=strategy, cfg=cfg, **opts)
        elif (spec.kind == "serve" and spec.resources.elastic
                and spec.serve.replicas > 1):
            from repro.core.executor import ElasticFleetServeExecutor
            s = spec.serve
            ex = ElasticFleetServeExecutor(
                clock, net, replicas=s.replicas,
                nodes_per_replica=spec.resources.n_nodes,
                n_requests=s.n_requests, max_new=s.max_new,
                tenant=s.tenant, ttft_slo_s=s.ttft_slo_s,
                strategy=strategy, engine_config=spec.engine_config(),
                cfg=cfg, **opts).bind(mc)
        elif spec.kind == "serve" and spec.resources.elastic:
            from repro.core.executor import ElasticServeExecutor
            s = spec.serve
            ex = ElasticServeExecutor(
                clock, net, n_requests=s.n_requests, max_new=s.max_new,
                strategy=strategy, engine_config=spec.engine_config(),
                cfg=cfg, **opts).bind(mc)
        elif spec.kind == "serve" and spec.serve.replicas > 1:
            from repro.core.executor import FleetServeExecutor
            s = spec.serve
            ex = FleetServeExecutor(
                clock, net, replicas=s.replicas,
                nodes_per_replica=spec.resources.n_nodes,
                n_requests=s.n_requests, max_new=s.max_new,
                tenant=s.tenant, ttft_slo_s=s.ttft_slo_s,
                strategy=strategy, engine_config=spec.engine_config(),
                cfg=cfg, **opts)
        elif spec.kind == "serve":
            from repro.core.executor import ServeExecutor
            s = spec.serve
            ex = ServeExecutor(
                clock, net, n_requests=s.n_requests, max_new=s.max_new,
                strategy=strategy, engine_config=spec.engine_config(),
                cfg=cfg, **opts)
        else:
            ex = _DryRunExecutor(clock, net, strategy=strategy, **opts)
        if hasattr(ex, "phase_cb"):
            ex.phase_cb = self._phase
        self._executors[key] = ex
        return ex

    # -- dispatch + lifecycle ----------------------------------------------
    def _dispatch(self, job: Job, rset, done):
        handle = self.handles.get(job.jobid)
        if handle is None:
            return self._fallback(job, rset, done)
        handle._transition(BOUND, hosts=list(rset.hosts))
        handle._transition(RUNNING)

        def finish(result: str, walltime: float):
            # same guard as FluxInstance._make_done: a completion
            # callback that fires after the job was requeued (node
            # loss raced it) is stale — the handle must not go
            # terminal, or the re-placement would be an illegal
            # transition out of Completed
            if job.state == JobState.RUN:
                # stamp BEFORE the transition so terminal-phase
                # listeners (pipeline gates) see handle.result()
                handle._stamp_result(result)
                handle._transition(COMPLETED if result == "completed"
                                   else FAILED, result=result)
            done(result, walltime)

        handle.executor(job, rset, finish)

    def _phase(self, jobid: int, phase: str, **detail):
        """Elastic executors report Resizing/Running through here."""
        handle = self.handles.get(jobid)
        if handle is not None and not handle.done:
            handle._transition(phase, **detail)
