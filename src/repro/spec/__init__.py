"""Declarative workload API: one CRD-style spec, reconciled into every
executor.

* ``workload``  — :class:`WorkloadSpec` (kind ``train`` | ``serve`` |
                  ``dryrun``), serializable with strict
                  ``to_dict``/``from_dict`` round-trip and structured
                  submit-time validation (:class:`SpecError`);
* ``handle``    — :class:`WorkloadHandle`, the observable lifecycle
                  ``Pending -> Bound -> Running -> Resizing ->
                  Completed/Failed`` behind ``status()``/``events()``;
* ``reconcile`` — :class:`WorkloadReconciler`, the single submission
                  path ``FluxInstance.apply`` delegates to;
* ``loader``    — ``load_spec`` / ``check_spec`` for the ``--spec``
                  CLI flag and the spec lint.
"""
from repro.spec.handle import (  # noqa: F401
    BOUND, COMPLETED, FAILED, PENDING, PHASES, RESIZING, RUNNING,
    WorkloadHandle,
)
from repro.spec.loader import check_spec, load_spec  # noqa: F401
from repro.spec.reconcile import WorkloadReconciler  # noqa: F401
from repro.spec.workload import (  # noqa: F401
    KINDS, DryRunSpec, ResourceSpec, ServeSpec, SpecError, TrainSpec,
    WorkloadSpec,
)
