"""arctic-480b [moe] — dense-MoE hybrid: 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base]
35L d_model=7168 56H (GQA kv=8) d_ff=4864, MoE 128e top-2, vocab=32000.
Every layer carries an MoE FFN (128 experts of d_ff=4864) in parallel with
a dense residual FFN.  Adafactor is the production optimizer choice at this
scale (AdamW fp32 states would exceed 16 GB/chip on a single v5e pod).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(
        n_experts=128, top_k=2, d_ff_expert=4864, every=1,
        dense_residual=True, d_ff_dense=4864, capacity_factor=1.25),
    optimizer="adafactor",
    opt_state_dtype="bfloat16",
    source="hf:Snowflake/snowflake-arctic-base",
)

SMOKE = ModelConfig(
    name="arctic-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=96, every=1,
                  dense_residual=True, d_ff_dense=96),
    optimizer="adafactor",
)
