"""granite-moe-1b-a400m [moe] — 32 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base]
24L d_model=1024 16H (GQA kv=8) d_ff=512/expert, MoE 32e top-8, vocab=49155.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512, every=1,
                  capacity_factor=1.25),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

SMOKE = ModelConfig(
    name="granite-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab_size=256,
    tie_embeddings=True,
    moe=MoEConfig(n_experts=8, top_k=4, d_ff_expert=64, every=1),
)
