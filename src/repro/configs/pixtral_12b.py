"""pixtral-12b [vlm] — pixtral-ViT frontend (STUB) + mistral-nemo decoder.

[hf:mistralai/Pixtral-12B-2409; unverified]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072.
The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings that are prepended to the token stream.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000_000.0,
    frontend="vision",
    source="hf:mistralai/Pixtral-12B-2409; unverified",
)

SMOKE = ModelConfig(
    name="pixtral-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    frontend="vision",
)
