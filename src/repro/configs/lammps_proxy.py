"""lammps-proxy — the paper's own workload stand-in.

The Flux Operator paper benchmarks LAMMPS (a CORAL-2 scalable-science
proxy) under two operators.  Our equivalent "application container" is a
small compute-bound transformer step; orchestration benchmarks submit this
as the job payload.  It is NOT one of the ten assigned architectures.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="lammps-proxy",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=8,
    d_ff=1024,
    vocab_size=1024,
    source="paper §4 proxy",
)

SMOKE = ModelConfig(
    name="lammps-proxy-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
)
