"""whisper-base [audio] — encoder-decoder; conv frontend STUB.

[arXiv:2212.04356; unverified]
6L d_model=512 8H d_ff=2048 vocab=51865.  Enc-dec: 6 encoder + 6 decoder
layers, LayerNorm + GeLU, sinusoidal positions.  The conv1d audio frontend
is a STUB per the assignment — ``input_specs()`` provides precomputed
frame embeddings for the encoder.  Being enc-dec (not encoder-only) the
decode shapes run; long_500k is skipped (full attention).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,               # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    norm_type="layernorm",
    mlp_type="gelu",
    pos_type="sinusoidal",
    encoder_layers=6,
    encoder_seq_divisor=2,    # encoder frames = seq_len // 2 (conv stride-2 stub)
    frontend="audio",
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)

SMOKE = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    norm_type="layernorm",
    mlp_type="gelu",
    pos_type="sinusoidal",
    encoder_layers=2,
    encoder_seq_divisor=2,
    frontend="audio",
    tie_embeddings=True,
)
