from repro.configs.base import (  # noqa: F401
    BASELINE, OPTIMIZED, SHAPES, STRATEGIES, ZERO3, MambaConfig, ModelConfig, MoEConfig,
    ShardingStrategy, TrainConfig, WorkloadShape, XLSTMConfig, replace,
    shape_applicable,
)
from repro.configs.registry import ARCH_IDS, EXTRA_IDS, all_configs, get, smoke  # noqa: F401
