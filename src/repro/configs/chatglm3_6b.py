"""chatglm3-6b [dense] — RoPE 2d (partial rotary), GQA kv=2, QKV bias.

[arXiv:2406.12793; hf:THUDM/chatglm3-6b]
28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
ChatGLM applies rotary embeddings to half of each head dim ("2d RoPE")
and uses bias on the fused QKV projection.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    rope_fraction=0.5,          # 2d rope: rotate half the head dim
    qkv_bias=True,
    norm_type="rmsnorm",
    mlp_type="swiglu",
    source="arXiv:2406.12793; hf",
)

SMOKE = ModelConfig(
    name="chatglm3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    rope_fraction=0.5,
    qkv_bias=True,
)
