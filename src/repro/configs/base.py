"""Configuration dataclasses for models, workload shapes and runs.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``WorkloadShape``s.  A (ModelConfig, WorkloadShape,
MeshSpec, ShardingStrategy) tuple fully determines one dry-run cell.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# --------------------------------------------------------------------------
# Sub-configs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (token-choice top-k, capacity dispatch)."""

    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    # Apply MoE to every ``every``-th position of the block pattern (1 = all).
    every: int = 1
    # Arctic-style parallel dense residual FFN next to the MoE branch.
    dense_residual: bool = False
    d_ff_dense: int = 0
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3


@dataclass(frozen=True)
class MambaConfig:
    """Jamba-style Mamba (selective SSM) block settings."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block settings (mLSTM matrix memory / sLSTM scalar memory)."""

    n_heads: int = 4
    expand: int = 2          # up-projection factor inside the cell
    d_conv: int = 4          # causal conv in mLSTM pre-projection
    chunk_size: int = 64     # chunkwise-parallel training chunk


# --------------------------------------------------------------------------
# Model config
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    d_head: int = 0                  # 0 -> d_model // n_heads

    # --- attention details ---
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # chatglm3 2d-RoPE: rotate half the head dim
    qkv_bias: bool = False
    causal: bool = True

    # --- norm / mlp / positions ---
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    mlp_type: str = "swiglu"         # swiglu | gelu
    pos_type: str = "rope"           # rope | sinusoidal | none
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- block pattern ---
    # The layer stack is ``n_layers`` long; kinds cycle through this pattern
    # (super-block).  n_layers must be divisible by len(block_pattern).
    block_pattern: Tuple[str, ...] = ("attn",)   # attn | mamba | mlstm | slstm

    # --- family extensions ---
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0          # >0 -> enc-dec with cross attention
    encoder_seq_divisor: int = 1     # encoder frames = seq_len // divisor

    # --- modality frontend stub ---
    frontend: Optional[str] = None   # audio | vision | None

    # --- optimizer choice (production default per arch) ---
    optimizer: str = "adamw"         # adamw | adafactor
    opt_state_dtype: str = "float32"  # float32 | bfloat16 (memory pressure)

    # --- provenance ---
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    @property
    def n_repeats(self) -> int:
        assert self.n_layers % self.pattern_len == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern of length {self.pattern_len}")
        return self.n_layers // self.pattern_len

    @property
    def attention_free(self) -> bool:
        return all(k != "attn" for k in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if context cost does not grow quadratically (SSM / hybrid)."""
        return any(k in ("mamba", "mlstm", "slstm") for k in self.block_pattern)

    def n_params(self) -> int:
        """Analytic parameter count (embeddings included once)."""
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        total = self.vocab_size * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d                 # lm head
        def attn_params() -> int:
            p = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
            if self.qkv_bias:
                p += h * hd + 2 * kv * hd
            return p
        def mlp_params(dff: int) -> int:
            if dff == 0:
                return 0
            n_in = 2 if self.mlp_type == "swiglu" else 1
            return n_in * d * dff + dff * d
        def mamba_params() -> int:
            mc = self.mamba or MambaConfig()
            d_in = mc.expand * d
            dtr = mc.dt_rank or -(-d // 16)
            return (d * 2 * d_in + d_in * mc.d_conv
                    + d_in * (dtr + 2 * mc.d_state) + dtr * d_in
                    + d_in * mc.d_state + d_in + d_in * d)
        def xlstm_params(kind: str) -> int:
            xc = self.xlstm or XLSTMConfig()
            d_in = xc.expand * d
            if kind == "mlstm":
                return (d * 2 * d_in + d_in * xc.d_conv + 3 * d_in * d_in // 1
                        + 3 * xc.n_heads * (d_in // xc.n_heads)  # gates
                        + d_in * d)
            return (4 * d * d_in + 4 * d_in * (d_in // xc.n_heads)
                    + d_in * d)
        for i, kind in enumerate(self.block_pattern):
            reps = self.n_repeats
            if kind == "attn":
                blk = attn_params()
            elif kind == "mamba":
                blk = mamba_params()
            elif kind in ("mlstm", "slstm"):
                blk = xlstm_params(kind)
            else:
                raise ValueError(kind)
            # feed-forward / moe on this position
            if self.moe is not None and (i % self.moe.every) == (self.moe.every - 1):
                blk += self.moe.n_experts * mlp_params(self.moe.d_ff_expert) // 1
                blk += self.d_model * self.moe.n_experts  # router
                if self.moe.dense_residual:
                    blk += mlp_params(self.moe.d_ff_dense)
            elif kind == "attn" or kind == "mamba":
                blk += mlp_params(self.d_ff)
            total += blk * reps
        # encoder stack (attention + mlp, non-causal, cross-attn in decoder)
        if self.encoder_layers:
            enc = (attn_params() + mlp_params(self.d_ff)) * self.encoder_layers
            xattn = attn_params() * self.n_layers   # decoder cross-attention
            total += enc + xattn
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.n_params()
        full = self.n_params()
        def mlp_params(dff: int) -> int:
            n_in = 2 if self.mlp_type == "swiglu" else 1
            return n_in * self.d_model * dff + dff * self.d_model
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if (i % self.moe.every) == (self.moe.every - 1))
        inactive = (self.moe.n_experts - self.moe.top_k) * \
            mlp_params(self.moe.d_ff_expert) * n_moe_layers
        return full - inactive


# --------------------------------------------------------------------------
# Workload shapes
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class WorkloadShape:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k":    WorkloadShape("train_4k", "train", 4_096, 256),
    "prefill_32k": WorkloadShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  WorkloadShape("decode_32k", "decode", 32_768, 128),
    "long_500k":   WorkloadShape("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: WorkloadShape) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs; reason when skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""


# --------------------------------------------------------------------------
# Training / run config
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    param_dtype: str = "float32"      # master params
    compute_dtype: str = "bfloat16"
    grad_accum: int = 1
    remat: bool = True
    seed: int = 0


@dataclass(frozen=True)
class ShardingStrategy:
    """Named sharding strategy; see dist/sharding.py for the rule tables."""

    name: str = "baseline"
    # baseline : DP over data(+pod), TP over model, ZeRO-1 opt states.
    # fsdp     : + params/grads sharded over data (ZeRO-3), seq-parallel
    #            residual stream, EP experts, sharded KV caches.
    fsdp_params: bool = False
    seq_shard_activations: bool = False
    expert_parallel: bool = True
    # decode-time KV cache sequence sharding axis ("model" | "none")
    kv_seq_axis: str = "model"
    # hierarchical two-phase collective schedule over (pod, data):
    # reduce-scatter inside each pod over the fast data axis, all-reduce
    # the shards across pods over the slow pod axis, all-gather back
    # (see repro/comm/collectives.py)
    hierarchical_collectives: bool = False
    # int8 error-feedback compression on cross-pod gradient reduction
    compress_cross_pod: bool = False
    # logical pod count the compression schema is sized for: the
    # error-feedback residual carries one row per pod payload, and its
    # SHAPE must not depend on the live mesh (elastic remesh reshards
    # the residual with the rest of the train state, so the schema is a
    # function of the strategy alone; meshes whose pod tier differs
    # sync uncompressed with a warning)
    compress_pods: int = 2
    # contiguous fp32 elements per int8 scale (quantization block)
    compress_block: int = 256
    # number of gradient-sync buckets (1 = one monolithic sync after
    # the full backward).  >1 partitions the param tree into
    # ~byte-balanced buckets in REVERSE-layer order and launches each
    # bucket's cross-pod phase as soon as its gradients are final, so
    # DCN time hides behind the remaining backward compute (see
    # repro/comm/bucketing.py and repro/comm/overlap.py)
    comm_buckets: int = 1
    # hierarchical MoE dispatch: shard experts over the pod tier too
    # (``expert`` -> (pod, model)) and route dispatch/combine as
    # pod-local exchange + cross-pod transfer of only the tokens whose
    # expert lives in another pod (see models/moe.py)
    hierarchical_moe: bool = False
    # error instead of falling back to flat sync when the mesh cannot
    # honor the requested comm schedule (no pod tier, pod mismatch)
    comm_strict: bool = False
    # tensor parallelism over the model axis; when False the model axis
    # becomes a second FSDP/data axis (pure ZeRO-3 over all 256 chips)
    tensor_parallel: bool = True


BASELINE = ShardingStrategy(name="baseline")
OPTIMIZED = ShardingStrategy(
    name="optimized", fsdp_params=True, seq_shard_activations=True,
    expert_parallel=True, hierarchical_collectives=True)
# beyond-paper: all 256 chips as one FSDP domain; params gathered bf16
# per layer, activations fully local (1 batch row per chip at gb=256)
ZERO3 = ShardingStrategy(
    name="zero3", fsdp_params=True, seq_shard_activations=False,
    expert_parallel=True, tensor_parallel=False)

STRATEGIES = {"baseline": BASELINE, "optimized": OPTIMIZED,
              "zero3": ZERO3}


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
