"""Architecture registry: ``get(name)`` and ``smoke(name)``.

Each assigned architecture lives in its own module (``configs/<id>.py``,
dashes become underscores) and exposes ``CONFIG`` (the exact published
config) and ``SMOKE`` (a reduced same-family config for CPU smoke tests).
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "chatglm3-6b",
    "yi-6b",
    "qwen2-72b",
    "deepseek-67b",
    "xlstm-1.3b",
    "arctic-480b",
    "granite-moe-1b-a400m",
    "pixtral-12b",
    "jamba-v0.1-52b",
    "whisper-base",
]

# The paper itself has no model; its workload proxy (LAMMPS / CORAL-2
# stand-in) is a small compute-bound config used by orchestration benches.
EXTRA_IDS = ["lammps-proxy"]


def _module(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS + EXTRA_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS + EXTRA_IDS}")
    return importlib.import_module(_module(arch_id)).CONFIG


def smoke(arch_id: str) -> ModelConfig:
    if arch_id not in ARCH_IDS + EXTRA_IDS:
        raise KeyError(f"unknown arch {arch_id!r}")
    return importlib.import_module(_module(arch_id)).SMOKE


def all_configs():
    return {a: get(a) for a in ARCH_IDS}
