"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf:ai21labs/Jamba-v0.1]
32L d_model=4096 32H (GQA kv=8) d_ff=14336, MoE 16e top-2, vocab=65536.
Each 8-layer Jamba block has 1 attention layer and 7 Mamba layers; MoE
replaces the FFN on every other layer.  Sub-quadratic for long context:
only 4/32 layers keep a KV cache.
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    pos_type="none",          # jamba uses no positional encoding
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=14336, every=2,
                  capacity_factor=1.25),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    source="arXiv:2403.19887; hf",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    pos_type="none",
    block_pattern=("mamba", "attn", "mamba", "mamba"),
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128, every=2),
    mamba=MambaConfig(d_state=8, d_conv=4, expand=2),
)
