"""xlstm-1.3b [ssm] — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM interleave).

[arXiv:2405.04517; unverified]
48L d_model=2048 4H (GQA kv=4) d_ff=0 vocab=50304.
d_ff=0: xLSTM blocks carry their own expand-2 up-projection; there is no
separate FFN.  Sub-quadratic: constant-size matrix/scalar memory state.
"""
from repro.configs.base import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    pos_type="none",
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMConfig(n_heads=4, expand=2, d_conv=4, chunk_size=64),
    tie_embeddings=False,
    source="arXiv:2405.04517; unverified",
)

SMOKE = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=256,
    pos_type="none",
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    xlstm=XLSTMConfig(n_heads=4, expand=2, d_conv=4, chunk_size=8),
)
