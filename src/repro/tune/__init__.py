"""XLA flag autotuning for the serving hot path.

``flagsets`` names the candidate compiler-flag bundles (scoped vmem,
windowed einsum, async collective fusion — the knobs that move decode
and prefill rooflines on TPU); ``autotune`` sweeps them per
(arch, mesh) cell, times the engine's jitted decode/prefill steps under
each, and records the winner to ``TUNED_FLAGS.json`` keyed by
``tune_key(arch, mesh)`` so launchers and benchmarks can load the tuned
set instead of re-sweeping.
"""
from repro.tune.flagsets import FLAG_SETS, flags_env  # noqa: F401
from repro.tune.autotune import (  # noqa: F401
    TUNED_FLAGS, load_tuned, record, sweep, tune_key)
