"""Sweep XLA flag sets over the serving steps; record the winners.

For one (arch, mesh) cell the sweep builds the engine's two hot jitted
programs — the fixed-slot paged decode step and the padded prefill step
— lowers each once, then compiles the SAME lowering under every
candidate flag set via ``compiler_options`` and times it.  Backends
that reject a flag (the CPU backend knows no ``xla_tpu_*``) mark the
set unsupported and fall back to the base compile, so the sweep runs —
and the plumbing stays testable — on any machine.

Winners persist to ``TUNED_FLAGS.json`` keyed by ``tune_key(arch,
mesh)`` (``"yi-6b@2x4"``): launchers and benchmarks look the tuned set
up by key instead of re-sweeping.

  PYTHONPATH=src python -m repro.tune.autotune --arch yi-6b \
      --dp 1 --tp 1 --iters 10

"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, Optional, Sequence

import numpy as np

from repro.tune.flagsets import FLAG_SETS

TUNED_FLAGS = "TUNED_FLAGS.json"


def tune_key(arch: str, mesh) -> str:
    """Stable lookup key for one (arch, mesh) cell: ``"arch@DxT"``.

    ``mesh`` is a jax Mesh or a plain shape sequence — the key encodes
    axis sizes only, in mesh order, so a relaunch on an equal-shaped
    mesh finds its tuned flags.
    """
    if hasattr(mesh, "shape"):
        dims = [int(s) for s in dict(mesh.shape).values()]
    else:
        dims = [int(s) for s in mesh]
    return f"{arch}@{'x'.join(str(d) for d in dims)}"


# --------------------------------------------------------------------------
# Timing one compiled step
# --------------------------------------------------------------------------


def _time_compiled(compiled, args, iters: int, warmup: int) -> float:
    """Median wall-clock ms per call of an AOT-compiled step."""
    import jax
    for _ in range(warmup):
        out = compiled(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(compiled(*args))
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times))


def _compile_with(lowered, flags: Dict[str, str]):
    """(compiled, supported): flag sets the backend rejects fall back to
    the base compile so every row of the sweep still yields a number."""
    if not flags:
        return lowered.compile(), True
    try:
        return lowered.compile(compiler_options=dict(flags)), True
    except Exception:
        return lowered.compile(), False


# --------------------------------------------------------------------------
# The sweep
# --------------------------------------------------------------------------


def sweep(cfg, mesh, *, strategy=None, n_slots: int = 4, page_size: int = 8,
          max_seq_len: int = 64, prompt_len: int = 16,
          flag_names: Optional[Sequence[str]] = None, iters: int = 10,
          warmup: int = 3, seed: int = 0) -> Dict:
    """Time decode + prefill under every flag set; return the cell record.

    Returns ``{"key_shape": ..., "results": {set: {"decode_ms",
    "prefill_ms", "supported"}}, "best": set, "flags": {...}}`` —
    ``best`` minimizes decode time (the serving steady state) over the
    supported sets, ties broken toward fewer flags.
    """
    import jax
    import jax.numpy as jnp

    from repro.configs import BASELINE
    from repro.configs.base import WorkloadShape
    from repro.dist import sharding as shd
    from repro.dist import steps as dsteps
    from repro.models.model import Model
    from repro.serve import paging

    strategy = strategy or BASELINE
    names = list(flag_names or FLAG_SETS)
    pps = max_seq_len // page_size
    layout = dsteps.PagedLayout(page_size=page_size, pages_per_slot=pps,
                                n_pages=n_slots * pps + 1)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    # -- decode: the fixed-slot paged step (no donation: one lowering is
    # re-compiled and re-run under every flag set)
    dshape = WorkloadShape(f"tune{n_slots}", "decode", max_seq_len, n_slots)
    raw_decode, din, dout = dsteps.build_decode_step(
        cfg, strategy, mesh, dshape, paged=layout)
    params = jax.tree_util.tree_map(jax.device_put, params, din[0])
    pool = jax.tree_util.tree_map(
        jax.device_put, paging.init_pool(cfg, n_slots, layout), din[1])
    bt = np.zeros((n_slots, pps), np.int32)
    for s in range(n_slots):           # every slot mid-sequence, 1 page
        bt[s, 0] = 1 + s
    dec_args = (params, pool, np.ones((n_slots, 1), np.int32), bt,
                np.full((n_slots,), page_size // 2, np.int32))
    dec_low = jax.jit(raw_decode, in_shardings=din,
                      out_shardings=dout).lower(*dec_args)

    # -- prefill: the padded fixed-capacity step
    pshape = WorkloadShape(f"tune_prefill{prompt_len}", "prefill",
                           prompt_len, 1)
    raw_prefill, pp_sh, bshard, pout = dsteps.build_prefill_step(
        cfg, strategy, mesh, pshape, ragged=True)
    pre_args = (params, {"tokens": np.ones((1, prompt_len), np.int32)},
                np.array([prompt_len - 1], np.int32))
    pre_low = jax.jit(raw_prefill, in_shardings=(
        pp_sh, {"tokens": bshard["tokens"]}, shd.replicated(mesh)),
        out_shardings=pout).lower(*pre_args)

    results: Dict[str, Dict] = {}
    for name in names:
        flags = FLAG_SETS[name]
        dec_c, dec_ok = _compile_with(dec_low, flags)
        pre_c, pre_ok = _compile_with(pre_low, flags)
        results[name] = {
            "decode_ms": _time_compiled(dec_c, dec_args, iters, warmup),
            "prefill_ms": _time_compiled(pre_c, pre_args, iters, warmup),
            "supported": bool(dec_ok and pre_ok),
            "n_flags": len(flags),
        }

    supported = [n for n in names if results[n]["supported"]] or names
    best = min(supported, key=lambda n: (results[n]["decode_ms"],
                                         results[n]["n_flags"]))
    return {
        "mesh_shape": dict(mesh.shape),
        "results": results,
        "best": best,
        "flags": dict(FLAG_SETS[best]),
    }


# --------------------------------------------------------------------------
# The TUNED_FLAGS.json registry
# --------------------------------------------------------------------------


def record(key: str, cell: Dict, path: str = TUNED_FLAGS) -> Dict:
    """Merge one swept cell into the tuned-flags file under ``key``."""
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data[key] = cell
    with open(path, "w") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return data


def load_tuned(key: str, path: str = TUNED_FLAGS) -> Optional[Dict[str, str]]:
    """The winning flag dict for ``key``, or None when never swept."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        data = json.load(f)
    cell = data.get(key)
    return None if cell is None else dict(cell.get("flags", {}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--dp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--out", default=TUNED_FLAGS)
    args = ap.parse_args()

    from repro.launch.mesh import resolve_workload
    cfg, mesh = resolve_workload(args.arch, dp=args.dp, tp=args.tp)
    cell = sweep(cfg, mesh, iters=args.iters)
    key = tune_key(args.arch, mesh)
    record(key, cell, args.out)
    print(f"{key}: best={cell['best']}")
    for name, row in cell["results"].items():
        mark = "" if row["supported"] else "  (unsupported, base timing)"
        print(f"  {name:<18} decode {row['decode_ms']:7.3f} ms  "
              f"prefill {row['prefill_ms']:7.3f} ms{mark}")


if __name__ == "__main__":
    main()
