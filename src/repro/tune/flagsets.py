"""Named XLA compiler-flag bundles for the serving sweep.

Each set is a dict of XLA debug options (flag name -> value, both
strings) — the spelling ``jax.jit(...).lower(...).compile(
compiler_options=...)`` accepts, and also renderable as an
``XLA_FLAGS`` environment string for cross-process application (the
launcher sets the env var before the backend initializes).

The bundles mirror the knobs production TPU serving stacks sweep:

* ``scoped_vmem`` — hand the scheduler a bigger scoped-vmem budget so
  fused decode kernels keep their working set on-chip;
* ``windowed_einsum`` — overlap sharded matmul collectives with the
  einsum they feed (helps tensor-parallel prefill);
* ``async_collectives`` — let all-gathers/reduce-scatters run async and
  fuse with surrounding ops (helps the data-tier page-pool exchange);
* ``latency_bound`` — the latency-hiding scheduler with collective
  overlap bounds tightened for small decode steps.

No jax import here: flag *names* must be loadable by the launcher
before any backend initialization.
"""
from __future__ import annotations

from typing import Dict

FLAG_SETS: Dict[str, Dict[str, str]] = {
    "base": {},
    "scoped_vmem": {
        "xla_tpu_scoped_vmem_limit_kib": "65536",
    },
    "windowed_einsum": {
        "xla_tpu_enable_windowed_einsum_for_all_gather": "true",
        "xla_tpu_enable_windowed_einsum_for_reduce_scatter": "true",
    },
    "async_collectives": {
        "xla_tpu_enable_async_collective_fusion": "true",
        "xla_tpu_enable_async_collective_fusion_fuse_all_gather": "true",
        "xla_tpu_overlap_compute_collective_tc": "true",
    },
    "latency_bound": {
        "xla_tpu_enable_latency_hiding_scheduler": "true",
        "xla_latency_hiding_scheduler_rerun": "1",
    },
}


def flags_env(name: str) -> str:
    """One flag set as an ``XLA_FLAGS`` fragment (empty for ``base``)."""
    fs = FLAG_SETS[name]
    return " ".join(f"--{k}={v}" for k, v in fs.items())
