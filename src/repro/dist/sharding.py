"""Parameter/cache sharding: logical axis names -> mesh axes.

Model code never names mesh axes.  Weights declare *logical* axes in
their ``PDef``s (``embed``, ``heads``, ``ff``, ``vocab``, ``expert``,
...) and a :class:`~repro.configs.base.ShardingStrategy` picks the rule
table that maps each logical axis onto zero or more mesh axes.  The
resolver then enforces the physical constraints the rule tables cannot
know about:

* a mesh axis that does not exist on this mesh is dropped (the same
  model runs on ``(data, model)``, ``(pod, data, model)`` and ``(1, 1)``
  smoke meshes);
* a mesh axis whose size does not divide the dimension is dropped
  (kv_heads=2 on model=4 stays replicated rather than crashing);
* a mesh axis is used at most once per spec (PartitionSpec rule).

``submesh_for`` is the bridge from the operator's resource layer: a
Fluxion ``ResourceSet`` (n hosts x chips/host) becomes a
``(data=hosts, model=chips)`` JAX sub-mesh over exactly the chips the
allocation names, degrading to whatever this process actually has.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ShardingStrategy

# A rule maps a logical axis name to a mesh axis, a tuple of mesh axes,
# or None (replicated).
Rule = Union[str, Tuple[str, ...], None]

# mesh axes that carry the data-parallel dimension, outermost first
DATA_AXES = ("pod", "data")


# --------------------------------------------------------------------------
# Mesh helpers
# --------------------------------------------------------------------------


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices=None) -> Mesh:
    """Version-compatible mesh builder (``AxisType`` landed after 0.4.37)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes), devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    if devices is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    arr = np.asarray(devices, dtype=object).reshape(tuple(shape))
    return Mesh(arr, tuple(axes))


def axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    """Product of the named mesh axes' sizes (1 for the empty tuple)."""
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
        if axes else 1


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in DATA_AXES if a in mesh.shape)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


# --------------------------------------------------------------------------
# Rule tables
# --------------------------------------------------------------------------


def param_rules(strategy: ShardingStrategy) -> Dict[str, Rule]:
    """Weight sharding rules (see models/layers.py for the axis names)."""
    tp = "model" if strategy.tensor_parallel else None
    if strategy.fsdp_params:
        # ZeRO-3; without TP the model axis joins the FSDP domain
        embed: Rule = "data" if strategy.tensor_parallel \
            else ("data", "model")
    else:
        embed = None
    if not strategy.expert_parallel:
        expert: Rule = None
    elif strategy.hierarchical_moe:
        # experts span the pod tier too (pod-major), so each pod holds
        # only n_experts/P expert weights and MoE dispatch has a
        # cross-pod hop to schedule (models/moe.py routes it
        # hierarchically); on a pod-less mesh this resolves back to
        # plain model-axis expert parallelism
        expert = ("pod", "model")
    else:
        expert = "model"
    return {
        "embed": embed,
        "heads": tp,
        "kv_heads": tp,
        "ff": tp,
        "vocab": tp,
        "expert": expert,
        "mamba_in": tp,
        "xl_in": tp,
        "xl_heads": tp,
    }


def opt_rules(strategy: ShardingStrategy) -> Dict[str, Rule]:
    """Optimizer-state rules: ZeRO-1 — states shard over the data axis
    even when the parameters themselves are replicated."""
    rules = dict(param_rules(strategy))
    if rules.get("embed") is None:
        rules["embed"] = "data"
    return rules


def cache_rules(strategy: ShardingStrategy) -> Dict[str, Rule]:
    """Decode-state rules (see transformer.cache_defs for the names).

    ``pages`` is the paged KV pool's page dim: ``paged_cache_defs`` only
    names it when the engine built a multi-shard allocator, so a pool
    shards over the data tier exactly when the host-side free lists are
    partitioned to match (slot-sharded pages; see serve/paging)."""
    tp = "model" if strategy.tensor_parallel else None
    return {
        "batch": DATA_AXES,
        "pages": DATA_AXES,
        "kv_seq": tp if strategy.kv_seq_axis == "model" else None,
        "kv_heads": tp,
        "mamba_in": tp,
        "xl_in": tp,
        "xl_heads": tp,
    }


# --------------------------------------------------------------------------
# Resolution
# --------------------------------------------------------------------------


def resolve_spec(shape: Sequence[int], axes: Sequence[Optional[str]],
                 rules: Dict[str, Rule], mesh: Mesh) -> PartitionSpec:
    """Logical axes -> PartitionSpec under this mesh's constraints."""
    used: set = set()
    spec = []
    for dim, name in zip(shape, axes):
        rule = rules.get(name) if name is not None else None
        cand: Tuple[str, ...] = () if rule is None else (
            rule if isinstance(rule, tuple) else (rule,))
        cand = tuple(a for a in cand if a in mesh.shape and a not in used)
        while cand and dim % axis_size(mesh, cand) != 0:
            cand = cand[:-1]
        if not cand:
            spec.append(None)
            continue
        used.update(cand)
        spec.append(cand[0] if len(cand) == 1 else cand)
    return PartitionSpec(*spec)


def tree_shardings(defs, mesh: Mesh, rules: Dict[str, Rule]):
    """PDef tree -> NamedSharding tree."""
    from repro.models import params as P   # deferred: models import us
    return P.tree_map(
        lambda d: NamedSharding(
            mesh, resolve_spec(d.shape, d.axes, rules, mesh)), defs)


def cache_shardings(cdefs, mesh: Mesh, strategy: ShardingStrategy):
    return tree_shardings(cdefs, mesh, cache_rules(strategy))


def batch_sharding(mesh: Mesh, ndim: int, global_batch: int,
                   strategy: ShardingStrategy,
                   seq_dim: Optional[int] = None) -> NamedSharding:
    """Model-input sharding: batch over the data axes, optionally the
    sequence dim over the model axis (sequence-parallel residuals)."""
    spec: list = [None] * ndim
    d = data_axes(mesh)
    if not strategy.tensor_parallel and "model" in mesh.shape:
        d = d + ("model",)
    while d and global_batch % axis_size(mesh, d) != 0:
        d = d[:-1]
    if d:
        spec[0] = d[0] if len(d) == 1 else d
    if (seq_dim is not None and strategy.tensor_parallel
            and "model" in mesh.shape and "model" not in d):
        spec[seq_dim] = "model"
    return NamedSharding(mesh, PartitionSpec(*spec))


# --------------------------------------------------------------------------
# ResourceSet -> sub-mesh (the operator/JAX bridge)
# --------------------------------------------------------------------------


def _pod_tier(rset) -> Optional[Tuple[int, int]]:
    """(n_pods, hosts_per_pod) when the allocation spans pods evenly.

    The pod tier only rises when it is well-formed: ≥ 2 distinct pods,
    the same host count in each, hosts grouped pod-contiguously (the
    graph numbers hosts pod-major, and matchers return sorted ids).
    Anything else — legacy ResourceSets without pod info, ragged spans
    — flattens to the classic (data, model) mesh.
    """
    pods = tuple(getattr(rset, "pods", ()) or ())
    if len(pods) != rset.n_hosts or len(set(pods)) < 2:
        return None
    if list(pods) != sorted(pods):
        return None
    counts = {p: pods.count(p) for p in set(pods)}
    if len(set(counts.values())) != 1:
        return None
    return len(counts), next(iter(counts.values()))


def submesh_for(rset, devices=None) -> Mesh:
    """Map a Flux ``ResourceSet`` allocation onto a JAX device sub-mesh.

    The allocation's chip ids index the process's device list directly
    — the resource graph drives physical placement.  Hosts become the
    ``data`` axis, chips-per-host the ``model`` axis; an allocation
    whose hosts span pods (the ``Host.pod`` field the graph carries)
    yields a ``(pod, data, model)`` mesh instead of flattening pod
    locality away, so the comm layer can schedule around the slow
    cross-pod links.  When the allocation names more chips than this
    process has (orchestration benches simulate fleets far larger than
    the dev box), the mesh degrades to the largest (hosts, chips) grid
    that fits, down to a single device.
    """
    devices = list(jax.devices() if devices is None else devices)
    nd = len(devices)
    cids = rset.chip_ids()
    if cids and len(cids) <= nd and max(cids) < nd:
        devs = [devices[c] for c in cids]
        tier = _pod_tier(rset)
        if tier is not None:
            n_pods, per_pod = tier
            shape: Tuple[int, ...] = (n_pods, per_pod,
                                      rset.chips_per_host)
            axes: Tuple[str, ...] = ("pod", "data", "model")
        else:
            shape = (rset.n_hosts, rset.chips_per_host)
            axes = ("data", "model")
    else:
        hosts = max(1, min(rset.n_hosts, nd))
        chips = max(1, min(rset.chips_per_host, nd // hosts))
        devs = devices[:hosts * chips]
        shape = (hosts, chips)
        axes = ("data", "model")
    arr = np.asarray(devs, dtype=object).reshape(shape)
    return Mesh(arr, axes)
