"""Sharded step builders — the one step API every surface consumes.

``build_train_step`` / ``build_prefill_step`` / ``build_decode_step``
return pure step functions plus the NamedSharding trees for their
inputs/outputs, so the trainer, the serving launcher, the dry-run's
compile-only lowering and the operator's submesh executor all run the
exact same code path; only the mesh differs.  Each step body enters
``activation_sharding(mesh, strategy)`` so the models' ``constrain``
marks resolve while jit traces.

Train state is a plain dict ``{"params", "opt", "step"}`` with PDef
schemas behind it, so the checkpoint manager can materialize abstract
templates and reshard restores across mesh changes.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import comm
from repro.configs.base import (ModelConfig, ShardingStrategy, TrainConfig,
                                WorkloadShape)
from repro.dist import sharding as shd
from repro.dist.actsharding import activation_sharding, activation_spec
from repro.models import params as P
from repro.models import transformer
from repro.models.layers import PagedView
from repro.models.model import Model, input_specs
from repro.optim import make_optimizer, opt_state_defs

METRIC_KEYS = ("loss", "xent", "moe_aux")


# --------------------------------------------------------------------------
# Train state: schema, init, shardings
# --------------------------------------------------------------------------


def train_state_defs(cfg: ModelConfig,
                     strategy: Optional[ShardingStrategy] = None) -> Dict:
    """State schema.  A strategy with ``compress_cross_pod`` adds the
    comm layer's error-feedback residual under ``comm/ef`` — schema'd
    by (cfg, strategy) alone, never by the live mesh, so checkpoints
    reshard across elastic resizes exactly like params and opt state."""
    model_defs = Model(cfg).param_defs()
    defs = {"params": model_defs, "opt": opt_state_defs(cfg, model_defs)}
    if strategy is not None and strategy.compress_cross_pod:
        defs["comm"] = {"ef": comm.ef_defs(model_defs, strategy)}
    return defs


def abstract_train_state(cfg: ModelConfig, tcfg: TrainConfig,
                         strategy: Optional[ShardingStrategy] = None) -> Dict:
    defs = train_state_defs(cfg, strategy)
    out = {
        "params": P.abstract_params(defs["params"],
                                    jnp.dtype(tcfg.param_dtype)),
        "opt": P.abstract_params(defs["opt"]),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if "comm" in defs:
        out["comm"] = P.abstract_params(defs["comm"])
    return out


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, key,
                     strategy: Optional[ShardingStrategy] = None) -> Dict:
    defs = train_state_defs(cfg, strategy)
    kp, ko = jax.random.split(key)
    out = {
        "params": P.init_params(defs["params"], kp,
                                jnp.dtype(tcfg.param_dtype)),
        "opt": P.init_params(defs["opt"], ko),
        "step": jnp.zeros((), jnp.int32),
    }
    if "comm" in defs:
        out["comm"] = P.init_params(defs["comm"], ko)   # zeros
    return out


def train_state_shardings(cfg: ModelConfig, strategy: ShardingStrategy,
                          mesh) -> Dict:
    defs = train_state_defs(cfg, strategy)
    out = {
        "params": shd.tree_shardings(defs["params"], mesh,
                                     shd.param_rules(strategy)),
        "opt": shd.tree_shardings(defs["opt"], mesh,
                                  shd.opt_rules(strategy)),
        "step": shd.replicated(mesh),
    }
    if "comm" in defs:
        out["comm"] = shd.tree_shardings(defs["comm"], mesh,
                                         comm.grad_rules(strategy))
    return out


def batch_shardings(cfg: ModelConfig, shape: WorkloadShape,
                    strategy: ShardingStrategy, mesh) -> Dict:
    return {k: shd.batch_sharding(mesh, len(v.shape), v.shape[0], strategy)
            for k, v in input_specs(cfg, shape).items()}


# --------------------------------------------------------------------------
# Train step
# --------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                     strategy: ShardingStrategy, mesh,
                     shape: WorkloadShape):
    """Returns (step_fn, state_shardings, batch_shardings).

    step_fn(state, batch) -> (new_state, metrics); metrics are scalar
    (loss, xent, moe_aux, grad_norm, lr).  Microbatched gradient
    accumulation when ``tcfg.grad_accum > 1``.

    When the strategy asks for hierarchical collectives and the mesh
    has a pod tier (``comm.resolve_policy``), the gradient sync routes
    through ``comm.sync_grads``: the microbatch loop keeps per-chunk
    gradients STACKED (one chunk per data-parallel shard, pod-major)
    instead of letting the partitioner emit a flat all-reduce, and the
    two-phase schedule — plus optional int8 error-feedback compression
    on the cross-pod hop — reduces them to the same mean.  With
    ``strategy.comm_buckets > 1`` the sync is emitted as one collective
    per reverse-layer bucket (``comm.sync_grads_bucketed``) so cross-pod
    transfers of deep layers overlap the shallow backward.  Otherwise
    the flat path below runs unchanged (``resolve_policy`` already
    warned, once, if the strategy asked for more than the mesh offers).
    """
    model = Model(cfg)
    update = make_optimizer(cfg, tcfg)
    cdt = jnp.dtype(tcfg.compute_dtype)
    ga = max(tcfg.grad_accum, 1)

    policy = comm.resolve_policy(strategy, mesh)
    dp_world = shd.axis_size(mesh, shd.data_axes(mesh))
    n_chunks = ga * max(dp_world, 1)
    if policy.hierarchical and shape.global_batch % n_chunks != 0:
        comm.degrade(strategy, f"global batch {shape.global_batch} does "
                     f"not divide into {n_chunks} chunks "
                     f"(grad_accum={ga} x dp={dp_world})", mesh=mesh)
        policy = comm.CommPolicy()

    def loss_fn(p, mb):
        loss, metrics = model.loss(p, mb, remat=tcfg.remat,
                                   compute_dtype=cdt)
        return loss, {k: metrics[k].astype(jnp.float32)
                      for k in METRIC_KEYS}

    def hier_grads(state, batch):
        """Stacked-chunk gradients routed through comm.sync_grads.

        vmap over the dp chunk dim keeps every (pod, data) slot's
        backward concurrent (a scan here would serialize dp_world
        parallel shards); grad_accum microbatches accumulate into ONE
        dp-stacked buffer in the scan carry, so memory stays at a
        single gradient copy per device like the flat path.  Chunks
        nest (accum, pod, data)-major, so the row set each POD owns is
        invariant under data-tier resizes — elastic remesh cannot
        perturb what the compressor sees.
        """
        params = state["params"]

        def chunk_grad(p, mb):
            return jax.value_and_grad(loss_fn, has_aux=True)(p, mb)

        def dp_grads(mbs):
            (_, m), g = jax.vmap(chunk_grad, in_axes=(None, 0))(params,
                                                                mbs)
            return g, m

        n_dp = max(dp_world, 1)
        micro = jax.tree_util.tree_map(
            lambda a: a.reshape((ga, n_dp, a.shape[0] // n_chunks)
                                + a.shape[1:]), batch)
        if ga == 1:
            stacked, ms = dp_grads(jax.tree_util.tree_map(
                lambda a: a[0], micro))
        else:
            def body(carry, mbs):
                gacc, macc = carry
                g, m = dp_grads(mbs)
                gacc = jax.tree_util.tree_map(
                    lambda x, y: x + y.astype(jnp.float32), gacc, g)
                macc = {k: macc[k] + m[k] for k in METRIC_KEYS}
                return (gacc, macc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros((n_dp,) + p.shape, jnp.float32),
                params)
            m0 = {k: jnp.zeros((n_dp,), jnp.float32)
                  for k in METRIC_KEYS}
            (gsum, msum), _ = jax.lax.scan(body, (g0, m0), micro)
            stacked = jax.tree_util.tree_map(lambda g: g / ga, gsum)
            ms = {k: v / ga for k, v in msum.items()}
        residual = (state["comm"]["ef"]
                    if policy.compress and "comm" in state else None)
        # one collective per bucket, reverse-layer order, so each
        # bucket's cross-pod phase is dispatched as soon as backward
        # finalized its gradients (comm_buckets == 1: monolithic sync)
        sync = (comm.sync_grads_bucketed if policy.buckets > 1
                else comm.sync_grads)
        grads, new_ef = sync(
            stacked, model.param_defs(), mesh, policy, strategy,
            residual=residual)
        metrics = {k: jnp.mean(ms[k]) for k in METRIC_KEYS}
        return grads, metrics, new_ef

    def step_fn(state, batch):
        with activation_sharding(mesh, strategy):
            params = state["params"]
            new_ef = None
            if policy.hierarchical:
                grads, metrics, new_ef = hier_grads(state, batch)
            elif ga == 1:
                (_, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, batch)
            else:
                micro = jax.tree_util.tree_map(
                    lambda a: a.reshape((ga, a.shape[0] // ga)
                                        + a.shape[1:]), batch)

                def body(carry, mb):
                    gacc, macc = carry
                    (_, m), g = jax.value_and_grad(
                        loss_fn, has_aux=True)(params, mb)
                    gacc = jax.tree_util.tree_map(
                        lambda a, b: a + b.astype(jnp.float32), gacc, g)
                    macc = {k: macc[k] + m[k] for k in METRIC_KEYS}
                    return (gacc, macc), None

                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                m0 = {k: jnp.zeros((), jnp.float32) for k in METRIC_KEYS}
                (grads, msum), _ = jax.lax.scan(body, (g0, m0), micro)
                grads = jax.tree_util.tree_map(lambda g: g / ga, grads)
                metrics = {k: v / ga for k, v in msum.items()}
            new_p, new_opt, stats = update(grads, state["opt"], params,
                                           state["step"])
            new_state = {"params": new_p, "opt": new_opt,
                         "step": state["step"] + 1}
            if "comm" in state:
                # the residual is train state even while a pod-less
                # mesh syncs flat: it must survive to the next mesh
                # that CAN compress (elastic remesh round-trip)
                new_state["comm"] = ({"ef": new_ef} if new_ef is not None
                                     else state["comm"])
            metrics = dict(metrics, grad_norm=stats["grad_norm"],
                           lr=stats["lr"])
        return new_state, metrics

    if ga > 1:
        assert shape.global_batch % ga == 0, (shape.global_batch, ga)
    return (step_fn, train_state_shardings(cfg, strategy, mesh),
            batch_shardings(cfg, shape, strategy, mesh))


# Compiled-step cache keyed on everything that determines the lowering:
# the (frozen, hashable) configs plus the mesh's axis names, shape and
# EXACT device set.  Elastic remesh rebuilds the step on every resize;
# without this cache a grow->shrink cycle that returns to an
# already-seen mesh would re-trace and re-compile from scratch, turning
# time-to-resume from milliseconds into seconds.  LRU-bounded so a
# long-lived operator process cycling through many (config, mesh)
# combinations cannot retain compiled executables forever.
_JIT_TRAIN_CACHE: "OrderedDict[Tuple, Tuple]" = OrderedDict()
_JIT_TRAIN_CACHE_MAX = 32


def mesh_cache_key(mesh) -> Tuple:
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(d.id for d in mesh.devices.flat))


def jit_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                   strategy: ShardingStrategy, mesh, shape: WorkloadShape):
    """``build_train_step`` + the canonical jit wrapping (state donated,
    metrics replicated) — what runtime consumers (trainer, submesh
    executor, elastic remesh) use; the dry-run keeps the raw step to
    lower it itself.  Memoized per (configs, workload, exact mesh)."""
    key = (cfg, tcfg, strategy, shape, mesh_cache_key(mesh))
    hit = _JIT_TRAIN_CACHE.get(key)
    if hit is not None:
        _JIT_TRAIN_CACHE.move_to_end(key)
        return hit
    step, sshard, bshard = build_train_step(cfg, tcfg, strategy, mesh,
                                            shape)
    jitted = jax.jit(step, in_shardings=(sshard, bshard),
                     out_shardings=(sshard, shd.replicated(mesh)),
                     donate_argnums=(0,))
    _JIT_TRAIN_CACHE[key] = (jitted, sshard, bshard)
    while len(_JIT_TRAIN_CACHE) > _JIT_TRAIN_CACHE_MAX:
        _JIT_TRAIN_CACHE.popitem(last=False)
    return jitted, sshard, bshard


# --------------------------------------------------------------------------
# Serving steps (prefill builds the cache; decode streams tokens)
# --------------------------------------------------------------------------


def _serving_param_shardings(cfg: ModelConfig, strategy: ShardingStrategy,
                             mesh):
    return shd.tree_shardings(Model(cfg).param_defs(), mesh,
                              shd.param_rules(strategy))


def _cache_defs(cfg: ModelConfig, shape: WorkloadShape):
    enc_len = (shape.seq_len // max(cfg.encoder_seq_divisor, 1)
               if cfg.encoder_layers else 0)
    return transformer.cache_defs(cfg, shape.global_batch, shape.seq_len,
                                  enc_len)


def _logits_sharding(cfg: ModelConfig, shape: WorkloadShape,
                     strategy: ShardingStrategy, mesh):
    from jax.sharding import NamedSharding
    spec = activation_spec(mesh, strategy,
                           (shape.global_batch, cfg.vocab_size),
                           "act_batch", "act_vocab")
    return NamedSharding(mesh, spec)


def build_prefill_step(cfg: ModelConfig, strategy: ShardingStrategy,
                       mesh, shape: WorkloadShape, ragged: bool = False):
    """Returns (step, param_shardings, batch_shardings, out_shardings);
    step(params, batch) -> (last_logits, caches).

    ``ragged``: the step takes an extra per-row ``last_index`` argument
    (position of the last real prompt token) and returns its logits —
    the serving engine pads every prompt to the step's fixed capacity.
    """
    model = Model(cfg)

    def step(params, batch):
        with activation_sharding(mesh, strategy):
            return model.prefill(params, batch)

    def ragged_step(params, batch, last_index):
        with activation_sharding(mesh, strategy):
            return model.prefill(params, batch, last_index=last_index)

    pshard = _serving_param_shardings(cfg, strategy, mesh)
    bshard = batch_shardings(cfg, shape, strategy, mesh)
    out_sh = (_logits_sharding(cfg, shape, strategy, mesh),
              shd.cache_shardings(_cache_defs(cfg, shape), mesh, strategy))
    return (ragged_step if ragged else step), pshard, bshard, out_sh


# --------------------------------------------------------------------------
# Paged decode (the serving engine's fixed-slot step)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Physical layout of the paged KV pool for one engine.

    ``n_pages`` counts the null page(s): never allocated, they absorb
    writes from empty slots and prompt padding.  A slot's capacity is
    ``pages_per_slot * page_size`` tokens.

    ``n_shards > 1`` partitions the pool over the data tier: shard ``r``
    owns the contiguous page range ``[r * n_pages/n_shards, (r+1) *
    n_pages/n_shards)`` with its own null page at the range's first id,
    and slots map onto shards block-wise (slot ``s`` -> shard ``s //
    (n_slots/n_shards)``) so a slot's block table only ever names local
    pages.  ``n_shards == 1`` is the classic single-pool layout with
    page 0 as THE null page.
    """

    page_size: int
    pages_per_slot: int
    n_pages: int
    n_shards: int = 1


def paged_cache_shardings(cfg: ModelConfig, layout: PagedLayout,
                          n_slots: int, strategy: ShardingStrategy, mesh):
    defs = transformer.paged_cache_defs(cfg, n_slots, layout.n_pages,
                                        layout.page_size,
                                        n_shards=layout.n_shards)
    return shd.cache_shardings(defs, mesh, strategy)


def _paged_table_shardings(mesh, paged: PagedLayout, n_slots: int):
    """Block-table / lengths shardings for a paged step: slot-sharded
    over the data tier when the pool itself is sharded (the slot->shard
    map keeps each data shard's table rows pointing at its local pages),
    replicated otherwise."""
    from jax.sharding import NamedSharding, PartitionSpec
    d = shd.data_axes(mesh)
    if (paged.n_shards > 1 and d and shd.axis_size(mesh, d) ==
            paged.n_shards and n_slots % paged.n_shards == 0):
        ax = d[0] if len(d) == 1 else d
        return (NamedSharding(mesh, PartitionSpec(ax, None)),
                NamedSharding(mesh, PartitionSpec(ax)))
    return shd.replicated(mesh), shd.replicated(mesh)


def build_decode_step(cfg: ModelConfig, strategy: ShardingStrategy,
                      mesh, shape: WorkloadShape,
                      paged: Optional[PagedLayout] = None):
    """Returns (step, in_shardings, out_shardings).

    Contiguous (default): step(params, caches, tokens, cache_index) ->
    (logits, new_caches) with one scalar write position for the batch.

    Paged: step(params, pool, tokens, block_table, lengths) ->
    (logits, new_pool).  ``shape.global_batch`` is the engine's fixed
    slot count — jit compiles once and continuous batching happens by
    mutating the block table / lengths between calls.
    """
    model = Model(cfg)
    pshard = _serving_param_shardings(cfg, strategy, mesh)
    tok_sh = shd.batch_sharding(mesh, 2, shape.global_batch, strategy)
    logit_sh = _logits_sharding(cfg, shape, strategy, mesh)

    if paged is not None:
        pool_sh = paged_cache_shardings(cfg, paged, shape.global_batch,
                                        strategy, mesh)

        def paged_step(params, pool, tokens, block_table, lengths):
            with activation_sharding(mesh, strategy):
                return model.decode_step(
                    params, pool, tokens, lengths,
                    paging=PagedView(block_table, lengths))

        bt_sh, len_sh = _paged_table_shardings(mesh, paged,
                                               shape.global_batch)
        in_sh = (pshard, pool_sh, tok_sh, bt_sh, len_sh)
        return paged_step, in_sh, (logit_sh, pool_sh)

    def step(params, caches, tokens, cache_index):
        with activation_sharding(mesh, strategy):
            return model.decode_step(params, caches, tokens, cache_index)

    cshard = shd.cache_shardings(_cache_defs(cfg, shape), mesh, strategy)
    in_sh = (pshard, cshard, tok_sh, shd.replicated(mesh))
    return step, in_sh, (logit_sh, cshard)


def build_mixed_step(cfg: ModelConfig, strategy: ShardingStrategy,
                     mesh, shape: WorkloadShape, paged: PagedLayout,
                     chunk: int):
    """The fused decode + chunked-prefill tick (perf: a long prompt no
    longer freezes TTFT/inter-token latency for every running slot).

    Returns (step, in_shardings, out_shardings) with

        step(params, pool, tokens, block_table, lengths,
             c_tokens, c_pages, c_start, c_len, c_null)
          -> (slot_logits, chunk_logits, new_pool)

    One jitted program makes two trunk passes sharing the params: the
    fixed-slot paged decode over ``tokens (n_slots, 1)`` (the host masks
    mid-prefill slots to their null page / length 0 in the view it
    passes), then a ``chunk``-token prefill pass for the single
    admitting slot — ``c_tokens (1, chunk)`` written at positions
    ``c_start..`` into the pages ``c_pages (1, pages_per_slot)``, rows
    past ``c_len`` sinking into ``c_null``.  The two passes touch
    disjoint pages, so threading the pool through them in sequence is
    order-independent.  ``chunk_logits`` is the chunk rows' logits
    ``(chunk, vocab)``; the host reads row ``c_len - 1`` of a request's
    final chunk for its first sampled token.
    """
    assert not cfg.sub_quadratic, \
        "chunked prefill is attention-only (seq-mixers prefill exactly)"
    model = Model(cfg)
    pshard = _serving_param_shardings(cfg, strategy, mesh)
    tok_sh = shd.batch_sharding(mesh, 2, shape.global_batch, strategy)
    logit_sh = _logits_sharding(cfg, shape, strategy, mesh)
    pool_sh = paged_cache_shardings(cfg, paged, shape.global_batch,
                                    strategy, mesh)
    bt_sh, len_sh = _paged_table_shardings(mesh, paged, shape.global_batch)
    r = shd.replicated(mesh)

    def mixed_step(params, pool, tokens, block_table, lengths,
                   c_tokens, c_pages, c_start, c_len, c_null):
        with activation_sharding(mesh, strategy):
            logits, pool = model.decode_step(
                params, pool, tokens, lengths,
                paging=PagedView(block_table, lengths))
            c_logits, pool = model.prefill_chunk(
                params, pool, c_tokens,
                paging=PagedView(c_pages, c_start, n_valid=c_len,
                                 null_page=c_null))
        return logits, c_logits[0], pool

    in_sh = (pshard, pool_sh, tok_sh, bt_sh, len_sh, r, r, r, r, r)
    return mixed_step, in_sh, (logit_sh, r, pool_sh)


# dry-run compatibility name: "serve" cells are decode cells
build_serve_step = build_decode_step


def abstract_serve_inputs(cfg: ModelConfig, shape: WorkloadShape
                          ) -> Tuple[Dict, jax.ShapeDtypeStruct,
                                     jax.ShapeDtypeStruct]:
    caches = P.abstract_params(_cache_defs(cfg, shape), jnp.bfloat16)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    idx = jax.ShapeDtypeStruct((), jnp.int32)
    return caches, tokens, idx
