"""Activation sharding constraints.

Model code marks activations with *logical* ``act_*`` names
(``constrain(x, "act_batch", None, "act_ff")``).  Inside an
``activation_sharding(mesh, strategy)`` context those names resolve to
mesh axes through the strategy's rule table and become
``with_sharding_constraint``s; outside any context (single-device CPU
smoke tests, plain ``jax.jit``) ``constrain`` is the identity, so the
same model file runs anywhere.

The context is entered inside the step functions built by
``dist/steps.py``, which means it is active exactly while jit traces
the model — the constraints land in the lowered HLO and nothing
leaks across steps.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.configs.base import ShardingStrategy
from repro.dist.sharding import DATA_AXES, Rule, resolve_spec


def act_rules(strategy: ShardingStrategy) -> Dict[str, Rule]:
    """Activation rule table; ``act_*_force`` names apply regardless of
    the strategy's optional toggles (the call site has already decided
    sharding is required, e.g. heads unshardable on this mesh)."""
    tp = "model" if strategy.tensor_parallel else None
    return {
        "act_batch": DATA_AXES,
        "act_seq": tp if strategy.seq_shard_activations else None,
        "act_seq_force": tp,
        "act_heads": tp,
        "act_kv": tp,
        "act_kv_seq": tp if strategy.kv_seq_axis == "model" else None,
        "act_ff": tp,
        "act_vocab": tp,
        "act_expert": "model" if strategy.expert_parallel else None,
        # hierarchical MoE: the expert HOME dim (which pod owns the
        # expert) shards over the pod tier; the resolver then keeps
        # ``act_batch`` off ``pod`` in the same spec (axis used once),
        # which is exactly the dispatched layout — tokens moved to
        # their expert's pod, batch sharded over data only
        "act_expert_home": "pod" if strategy.hierarchical_moe else None,
        "act_inner": tp,
    }


@dataclasses.dataclass(frozen=True)
class _ActiveSharding:
    mesh: Mesh
    strategy: ShardingStrategy
    rules: Dict[str, Rule]


class _Ctx(threading.local):
    def __init__(self):
        self.stack = []


_CTX = _Ctx()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, strategy: ShardingStrategy):
    """Enter the mesh/strategy under which ``constrain`` resolves."""
    _CTX.stack.append(_ActiveSharding(mesh, strategy, act_rules(strategy)))
    try:
        yield _CTX.stack[-1]
    finally:
        _CTX.stack.pop()


def current() -> Optional[_ActiveSharding]:
    return _CTX.stack[-1] if _CTX.stack else None


def constrain(x, *names):
    """Constrain ``x`` dim-by-dim to the named logical activation axes.

    Identity when no ``activation_sharding`` context is active; inside
    one, unknown/None names and non-dividing axes replicate that dim.
    """
    ctx = current()
    if ctx is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(
            f"constrain: {len(names)} names for rank-{x.ndim} array")
    spec = resolve_spec(x.shape, names, ctx.rules, ctx.mesh)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def model_axis_divides(n: int) -> bool:
    """Whether the active tensor-parallel axis evenly divides ``n``
    (vacuously true off-mesh and without tensor parallelism)."""
    ctx = current()
    if ctx is None or not ctx.strategy.tensor_parallel:
        return True
    return n % ctx.mesh.shape.get("model", 1) == 0


def activation_spec(mesh: Mesh, strategy: ShardingStrategy, shape,
                    *names) -> PartitionSpec:
    """Resolve act names outside a context (output-sharding declarations)."""
    return resolve_spec(shape, names, act_rules(strategy), mesh)
