"""Distributed execution substrate.

One sharded-step API shared by every execution surface:

* ``sharding``    — logical-axis -> mesh-axis rule tables, PDef-tree ->
                    NamedSharding resolution, mesh-shape helpers, and the
                    ``submesh_for`` bridge from a Flux ``ResourceSet``
                    allocation to a JAX device sub-mesh;
* ``actsharding`` — activation constraints: ``constrain`` resolves
                    ``act_*`` logical names against the active
                    ``activation_sharding(mesh, strategy)`` context and is
                    the identity off-mesh (single-device CPU runs);
* ``steps``       — ``build_train_step`` / ``build_prefill_step`` /
                    ``build_decode_step`` plus train-state init/abstract
                    schemas, consumed by the trainer, the serving
                    launcher, the dry-run, and the submesh executor.
"""
from repro.dist import actsharding, sharding  # noqa: F401
from repro.dist.actsharding import (  # noqa: F401
    activation_sharding, constrain, model_axis_divides,
)
from repro.dist.sharding import (  # noqa: F401
    make_mesh, param_rules, replicated, resolve_spec, submesh_for,
)


def __getattr__(name):
    # ``steps`` imports the model facade; loading it lazily keeps the
    # models -> actsharding import chain acyclic.
    if name == "steps":
        import importlib
        return importlib.import_module("repro.dist.steps")
    raise AttributeError(name)
